"""JAX array backend vs the numpy batched plane (ISSUE 4).

The jax backend must reproduce ``evaluate_batch`` (numpy) — and through
it ``sweep_reference`` — record-for-record to ≤1e-9 relative on every
numeric field: the full acceptance grid (suite × 5 NPUs × 5 policies ×
4 knobs), randomized ragged stacks with empty and single-op workloads
mixed in, knob grids of size 1, and the ``sweep_grid`` fine-knob cross
product with SA-width variants. Also: the x64 requirement raises a
clear error instead of silently degrading to f32, and sharding the
stacked workload axis over a ``jax_compat`` mesh changes nothing.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core.backend import gap_index, get_backend  # noqa: E402
from repro.core.hw import NPUS, get_npu  # noqa: E402
from repro.core.opgen import (Op, Workload, paper_suite,  # noqa: E402
                              segmented_gaps)
from repro.core.policies import (POLICIES, PolicyKnobs,  # noqa: E402
                                 evaluate, evaluate_batch)
from repro.core.sweep import (sweep, sweep_grid,  # noqa: E402
                              sweep_reference)

from _sweep_equiv import RTOL  # noqa: E402
from _sweep_equiv import rel as _rel  # noqa: E402
from _sweep_equiv import assert_records_match as _assert_records_match  # noqa: E402,E501
from _sweep_equiv import assert_reports_match as _assert_reports_match  # noqa: E402,E501

KNOB_GRID = [
    PolicyKnobs(),
    PolicyKnobs(delay_scale=2.0),
    PolicyKnobs(delay_scale=0.5),
    PolicyKnobs(leak_off_logic=0.2, leak_sram_sleep=0.4,
                leak_sram_off=0.02),
]


def _require_x64():
    bk = get_backend("jax")
    if bk._x64_ctx is None and not bk.x64_enabled():
        pytest.skip("this jax has no scoped x64 switch and "
                    "jax_enable_x64 is off")
    return bk


# --------------------------------------------------------------------------
# acceptance grid: suite × 5 NPUs × 5 policies × 4 knobs
# --------------------------------------------------------------------------

def test_full_grid_matches_numpy_batched():
    """The ISSUE-4 acceptance grid, record-for-record ≤1e-9 with
    byte-identical ordering against the numpy batched path."""
    _require_x64()
    suite = paper_suite()
    npus = tuple(NPUS)
    ref = sweep(suite, npus, POLICIES, KNOB_GRID, backend="numpy")
    got = sweep(suite, npus, POLICIES, KNOB_GRID, backend="jax")
    key = ("workload", "npu", "policy", "knob_idx")
    assert [tuple(r[k] for k in key) for r in ref] \
        == [tuple(r[k] for k in key) for r in got]
    _assert_records_match(ref, got)


def test_matches_sweep_reference_loop_oracle():
    """Transitively through the numpy plane is not enough: hold the jax
    backend directly to the original one-evaluate-per-cell loop."""
    _require_x64()
    wls = paper_suite()[:3]
    grid = [PolicyKnobs(), PolicyKnobs(delay_scale=4.0)]
    ref = sweep_reference(wls, ("NPU-B", "NPU-E"), POLICIES, grid)
    got = sweep(wls, ("NPU-B", "NPU-E"), POLICIES, grid, backend="jax")
    _assert_records_match(ref, got)


# --------------------------------------------------------------------------
# randomized ragged stacks (empty + single-op workloads mixed in)
# --------------------------------------------------------------------------

def _random_workload(rng: np.random.Generator, i: int,
                     n_ops: int) -> Workload:
    ops = []
    for j in range(n_ops):
        kind = rng.random()
        flops_sa = float(rng.uniform(1e9, 5e12)) if kind < 0.45 else 0.0
        mm = None
        if flops_sa and rng.random() < 0.8:
            mm = (int(rng.integers(1, 4096)), int(rng.integers(1, 512)),
                  int(rng.integers(1, 4096)))
        ops.append(Op(
            f"op{j}", flops_sa=flops_sa,
            flops_vu=float(rng.uniform(1e8, 5e11))
            if rng.random() < 0.5 else 0.0,
            bytes_hbm=float(rng.uniform(1e6, 1e10))
            if rng.random() < 0.6 else 0.0,
            bytes_ici=float(rng.uniform(1e6, 1e9))
            if rng.random() < 0.15 else 0.0,
            sram_demand=int(rng.integers(0, 256 << 20)),
            matmul_dims=mm, count=int(rng.integers(1, 5))))
    return Workload(f"rand-{i}", "prefill", tuple(ops))


def test_randomized_ragged_stack_property():
    """Random ragged stack with empty and single-op workloads mixed in:
    the jax backend must match per-workload ``evaluate`` cell-for-cell
    (and the empty segments must come back as exact zeros)."""
    _require_x64()
    rng = np.random.default_rng(11)
    sizes = [0, 1, int(rng.integers(2, 30)), 0, 1,
             int(rng.integers(2, 30)), int(rng.integers(2, 30)), 0]
    wls = [_random_workload(rng, i, n) for i, n in enumerate(sizes)]
    grid = (PolicyKnobs(), PolicyKnobs(delay_scale=3.0),
            PolicyKnobs(leak_off_logic=0.0, delay_scale=0.25))
    npus = ("NPU-A", "NPU-E")
    res = evaluate_batch(wls, npus, POLICIES, grid, backend="jax")
    for wi, wl in enumerate(wls):
        for ai, npu in enumerate(npus):
            for pi, policy in enumerate(POLICIES):
                for ki, knobs in enumerate(grid):
                    want = evaluate(wl, npu, policy, knobs)
                    got = res.report(wi, ai, pi, ki)
                    _assert_reports_match(got, want,
                                          (wl.name, npu, policy, ki))
                    if not wl.ops:
                        assert got.runtime_s == 0.0
                        assert got.total_j == 0.0
    for rec in res.records():
        for v in rec.values():
            if isinstance(v, float):
                assert np.isfinite(v)


def test_knob_grid_of_size_one_and_single_workload():
    _require_x64()
    wl = paper_suite()[8]
    ref = sweep(wl, ("NPU-C",), POLICIES,
                [PolicyKnobs(delay_scale=2.0)], backend="numpy")
    got = sweep(wl, ("NPU-C",), POLICIES,
                [PolicyKnobs(delay_scale=2.0)], backend="jax")
    assert len(got) == len(POLICIES)
    _assert_records_match(ref, got)


def test_no_workloads_empty_result():
    _require_x64()
    res = evaluate_batch([], ("NPU-D",), POLICIES, backend="jax")
    assert res.shape == (0, 1, len(POLICIES), 1)
    assert res.records() == []


# --------------------------------------------------------------------------
# sweep_grid fine-knob entry point
# --------------------------------------------------------------------------

def test_sweep_grid_cross_product_equivalence():
    """A small §6.5 cross product: jax matches numpy record-for-record
    and the knob metadata columns carry the delay-major ordering."""
    _require_x64()
    wls = paper_suite()[:2]
    kw = dict(delay_scale=(0.5, 1.0, 2.0),
              leak_off_logic=(0.03, 0.2),
              leak_sram_sleep=(0.25,),
              leak_sram_off=(0.002, 0.02))
    ref = sweep_grid(wls, ("NPU-D",), POLICIES, backend="numpy", **kw)
    got = sweep_grid(wls, ("NPU-D",), POLICIES, backend="jax", **kw)
    assert len(got) == 2 * 1 * len(POLICIES) * 12
    _assert_records_match(ref, got)
    # delay-major ordering: leak_sram_off innermost
    k0 = [r for r in got if r["workload"] == wls[0].name
          and r["policy"] == POLICIES[0]]
    assert [r["delay_scale"] for r in k0[:4]] == [0.5] * 4
    assert [r["leak_sram_off"] for r in k0[:4]] == [0.002, 0.02] * 2


def test_sweep_grid_sa_width_axis():
    """``sa_width`` is a real knob axis (ISSUE 5): the NPU axis stays
    untouched, records carry the width in their ``sa_width`` column,
    the traced-saw jax kernel matches a direct evaluation on a
    width-replaced spec, and a non-native width genuinely changes the
    SA numbers."""
    _require_x64()
    wl = paper_suite()[4]  # prefill, SA-heavy
    res = sweep_grid(wl, ("NPU-D",), ("NoPG", "ReGate-HW"),
                     sa_width=(None, 256), backend="jax",
                     as_records=False)
    assert tuple(n.name for n in res.npus) == ("NPU-D",)
    recs = res.records()
    assert {r["npu"] for r in recs} == {"NPU-D"}
    assert {r["sa_width"] for r in recs} == {None, 256}
    native = [r for r in recs if r["sa_width"] is None
              and r["policy"] == "ReGate-HW"][0]
    wide = [r for r in recs if r["sa_width"] == 256
            and r["policy"] == "ReGate-HW"][0]
    assert native["runtime_s"] != wide["runtime_s"]
    # per-width cells equal a direct scalar evaluation with the knob
    want = evaluate(wl, "NPU-D", "ReGate-HW", PolicyKnobs(sa_width=256))
    assert _rel(wide["total_j"], want.total_j) <= RTOL
    # ... and a direct evaluation on the width-replaced spec (wider SA
    # also means higher peak FLOP/s — the derived sa_flops moved too)
    from repro.core.hw import with_sa_width
    spec = with_sa_width(get_npu("NPU-D"), 256)
    assert spec.sa_flops > get_npu("NPU-D").sa_flops
    want2 = evaluate(wl, spec, "ReGate-HW")
    assert _rel(wide["total_j"], want2.total_j) <= RTOL


def test_sa_width_knob_traced_vs_loop_oracle():
    """A width × delay grid through the jax kernel against the
    per-cell loop oracle (``sweep_reference``), which resolves widths
    through memoized ``hw.with_sa_width`` variant specs."""
    _require_x64()
    from repro.core.sweep import knob_product
    wls = paper_suite()[:2]
    grid = knob_product(delay_scale=(1.0, 3.0),
                        sa_width=(None, 64, 512))
    ref = sweep_reference(wls, ("NPU-A", "NPU-E"), POLICIES, grid)
    got = sweep(wls, ("NPU-A", "NPU-E"), POLICIES, grid, backend="jax")
    _assert_records_match(ref, got)


def test_pallas_occupancy_inside_sweep():
    """The Pallas ``sa_occupancy`` kernel, selected through the backend
    contract, reproduces the numpy sweep record-for-record (the
    ROADMAP's "whole jax sweep program stays on-device" step)."""
    _require_x64()
    from repro.core import backend as backend_mod
    from repro.core.sweep import knob_product
    wl = paper_suite()[4]
    grid = knob_product(delay_scale=(1.0, 2.0), sa_width=(None, 256))
    ref = sweep(wl, ("NPU-D",), POLICIES, grid, backend="numpy")
    prev = backend_mod.set_sa_occupancy_impl("pallas")
    try:
        got = sweep(wl, ("NPU-D",), POLICIES, grid, backend="jax")
    finally:
        backend_mod.set_sa_occupancy_impl(prev)
    _assert_records_match(ref, got)
    with pytest.raises(KeyError):
        backend_mod.set_sa_occupancy_impl("nope")


# --------------------------------------------------------------------------
# sharding over the stacked workload axis (jax_compat mesh)
# --------------------------------------------------------------------------

def test_jax_mesh_sharded_matches_unsharded():
    _require_x64()
    from repro.parallel import jax_compat
    mesh = jax_compat.make_mesh((len(jax.devices()),), ("wl",))
    wls = paper_suite()[:3]
    ref = sweep(wls, ("NPU-A", "NPU-D"), POLICIES, KNOB_GRID,
                backend="numpy")
    got = evaluate_batch(wls, ("NPU-A", "NPU-D"), POLICIES, KNOB_GRID,
                         backend="jax", jax_mesh=mesh).records()
    _assert_records_match(ref, got)


def test_jax_mesh_requires_jax_backend():
    with pytest.raises(ValueError, match="jax_mesh"):
        evaluate_batch(paper_suite()[:1], backend="numpy",
                       jax_mesh=object())


@pytest.mark.parametrize("axes", [("knob",), ("wl", "knob")])
def test_shard_map_mesh_matches_numpy(axes):
    """A mesh with a ``"knob"`` axis selects the explicit shard_map
    program (op columns psum-completed over ``wl``, pairs + knobs
    sharded over ``knob``); every topology must match the numpy oracle
    record-for-record — including knob/pair counts that do not divide
    the axis size (the padding path)."""
    _require_x64()
    from repro.core.sweep import knob_product
    from repro.parallel import jax_compat
    n_dev = len(jax.devices())
    shape = (n_dev,) if axes == ("knob",) else (1, n_dev)
    mesh = jax_compat.make_mesh(shape, axes)
    wls = paper_suite()[:3]
    grid = knob_product(delay_scale=(0.5, 1.0, 2.0),
                        leak_off_logic=(0.03, 0.2),
                        sa_width=(None, 256))
    ref = sweep(wls, ("NPU-B", "NPU-E"), POLICIES, grid,
                backend="numpy")
    got = evaluate_batch(wls, ("NPU-B", "NPU-E"), POLICIES, grid,
                         backend="jax", jax_mesh=mesh).records()
    _assert_records_match(ref, got)


# --------------------------------------------------------------------------
# x64 discipline
# --------------------------------------------------------------------------

def test_x64_disabled_raises_clear_error(monkeypatch):
    """Without a scoped x64 switch and with the global flag off, the
    jax backend must refuse loudly (f32 would silently violate the
    ≤1e-9 contract) and tell the user how to enable x64."""
    bk = get_backend("jax")
    monkeypatch.setattr(bk, "_x64_ctx", None)
    if bk.x64_enabled():
        pytest.skip("jax_enable_x64 is globally on in this session")
    with pytest.raises(RuntimeError, match="x64"):
        evaluate_batch(paper_suite()[:1], ("NPU-D",), ("NoPG",),
                       backend="jax")


def test_default_backend_steering(monkeypatch):
    """``set_default_backend`` steers ``backend=None`` callers (what
    ``benchmarks/run.py --backend jax`` relies on)."""
    _require_x64()
    from repro.core import backend as backend_mod
    wl = paper_suite()[0]
    ref = sweep(wl, policies=("NoPG",), backend="numpy")
    prev = backend_mod.set_default_backend("jax")
    try:
        got = sweep(wl, policies=("NoPG",))
    finally:
        backend_mod.set_default_backend(prev)
    _assert_records_match(ref, got)


# --------------------------------------------------------------------------
# fixed-shape gap index vs the ragged reduceat oracle
# --------------------------------------------------------------------------

def test_gap_index_matches_segmented_gaps():
    """Per-segment masked gap sums computed through the fixed-shape
    index must equal the ragged ``segmented_gaps`` chunking for random
    activity patterns with empty segments mixed in."""
    rng = np.random.default_rng(5)
    for _ in range(20):
        lens = rng.integers(0, 9, size=int(rng.integers(1, 7)))
        offsets = np.zeros(len(lens) + 1, np.int64)
        np.cumsum(lens, out=offsets[1:])
        n = int(offsets[-1])
        active = rng.random(n) < 0.4
        idle = np.where(active, 0.0, rng.random(n))
        gv_ref, gofs = segmented_gaps(active, idle, offsets)
        chunk_of_op, gap_seg = gap_index(active, offsets)
        n_gaps = len(gap_seg)
        gv = np.bincount(chunk_of_op, weights=idle,
                         minlength=n_gaps)[:n_gaps]
        w = len(lens)
        for thresh in (0.0, 0.3, 1.5):
            mask_ref = gv_ref > thresh
            ref = np.array([np.where(mask_ref[gofs[s]:gofs[s + 1]],
                                     gv_ref[gofs[s]:gofs[s + 1]],
                                     0.0).sum() for s in range(w)])
            mask = gv > thresh
            got = np.bincount(gap_seg[mask], weights=gv[mask],
                              minlength=w)[:w]
            np.testing.assert_allclose(got, ref, rtol=1e-12, atol=0)
