"""A day in the life of a 4096-chip NPU fleet, on the batched sweep
kernel (ISSUE 7).

Four tenant classes — diurnal chat decode + prefill, a bursty 70B
research tier, and a steady DLRM embedding service — generate over a
million requests across a 24h window (96 x 15-min epochs). Every epoch
is dispatched as exactly ONE batched ``evaluate_batch`` call over the
active (class-mix x policies x knob-grid) cube, the online SLO governor
re-tunes ``PolicyKnobs`` whenever queueing pressure inflates effective
runtimes past the relaxed SLO, and ``carbon.fleet_rollup`` turns the
summed per-chip joules into facility kWh / kgCO2e / USD.

  PYTHONPATH=src python examples/fleet_day.py [--backend jax]

The run is deterministic under the fixed seed (arrival traces follow
the ``core.perturb`` explicit-Generator fixed-draw-count contract), and
the script asserts in-line that the fleet carbon/cost totals reconcile
with the sum of per-record chip energies to <= 1e-9 relative.
"""
import argparse
import math
import time

from repro.core.carbon import CARBON_INTENSITY, PUE, USD_PER_KWH
from repro.core.fleet import ArrivalSpec, FleetScenario, WorkloadClass
from repro.core.opgen import dlrm_workload, llm_workload
from repro.core.policies import KnobGrid
from repro.core.sweep import SweepSession, sweep_fleet

REL_TOL = 1e-9


def build_scenario() -> FleetScenario:
    # Interactive chat rides the day curve (peak_frac=0.9: near-quiet
    # overnight troughs); the 70B research tier flash-crowds; DLRM
    # serving is steady background load.
    chat_decode = WorkloadClass(
        "chat-decode",
        llm_workload("llama3-8b", "decode", batch=8),
        ArrivalSpec("diurnal", rate_rps=10.0, peak_frac=0.9,
                    period_s=86400.0, phase_s=-21600.0),
        requests_per_invocation=8)
    chat_prefill = WorkloadClass(
        "chat-prefill",
        llm_workload("llama3-8b", "prefill", batch=1, seq=4096),
        ArrivalSpec("diurnal", rate_rps=10.0, peak_frac=0.9,
                    period_s=86400.0, phase_s=-21600.0))
    research = WorkloadClass(
        "research-70b",
        llm_workload("llama3-70b", "decode", batch=4, n_chips=8, tp=8),
        ArrivalSpec("bursty", rate_rps=1.5, burst_prob=0.15,
                    burst_factor=8.0),
        requests_per_invocation=4)
    ranking = WorkloadClass(
        "ranking-dlrm",
        dlrm_workload("M"),
        ArrivalSpec("poisson", rate_rps=3.0),
        requests_per_invocation=1024)
    return FleetScenario(
        classes=(chat_decode, chat_prefill, research, ranking),
        n_chips=4096, npu="NPU-D",
        policies=("NoPG", "ReGate-HW", "ReGate-Full"),
        duration_s=86400.0, epoch_s=900.0,
        slo_relax=1.2, seed=7, severity_levels=(0.0, 0.5, 1.0))


def check_reconciliation(report) -> None:
    """Fleet totals must equal the per-record chip-energy sums (plus
    unallocated-chip idle) and the carbon/cost roll-up must be exact
    arithmetic on those joules — both to <= 1e-9 relative."""
    for s in report.summary:
        pol = s["policy"]
        recs = [r for r in report.records if r["policy"] == pol]
        eps = [x for x in report.epoch_summary if x["policy"] == pol]
        direct = math.fsum(r["total_j"] for r in recs) \
            + math.fsum(x["unallocated_idle_j"] for x in eps)
        rel = abs(s["total_j"] - direct) / max(direct, 1e-300)
        assert rel <= REL_TOL, (pol, rel)
        kwh = s["total_j"] / 3.6e6
        for got, want in ((s["chip_kwh"], kwh),
                          (s["facility_kwh"], kwh * PUE),
                          (s["co2_kg"], kwh * PUE * CARBON_INTENSITY),
                          (s["cost_usd"], kwh * PUE * USD_PER_KWH)):
            assert abs(got - want) <= REL_TOL * max(abs(want), 1.0), pol
    print(f"reconciliation: totals match per-record sums and roll-up "
          f"arithmetic to <= {REL_TOL:g} relative, all policies")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default=None, choices=("numpy", "jax"),
                    help="array backend for every per-epoch batched "
                         "sweep call")
    args = ap.parse_args(argv)
    if args.backend:
        with SweepSession(backend=args.backend):
            return run()
    return run()


def run():
    scenario = build_scenario()
    grid = KnobGrid(window_scale=(0.5, 1.0, 2.0),
                    delay_scale=(1.0, 2.0))
    t0 = time.perf_counter()
    report = sweep_fleet(scenario, grid)
    wall = time.perf_counter() - t0

    assert report.n_chips == 4096
    assert report.requests_total >= 1_000_000, report.requests_total
    print(f"fleet day: {report.requests_total:,} requests over "
          f"{report.n_epochs} epochs x {report.epoch_s:.0f}s on "
          f"{report.n_chips} x {report.npu} chips "
          f"({len(report.class_names)} classes, "
          f"{grid.size}-knob grid, one batched sweep call per epoch) "
          f"in {wall:.2f}s wall")

    # a few epochs through the day: demand, congestion level, governor
    print("\nepoch samples (ReGate-Full):")
    eps = [s for s in report.epoch_summary
           if s["policy"] == "ReGate-Full"]
    for s in eps[:: max(1, len(eps) // 8)]:
        hour = s["epoch"] * report.epoch_s / 3600.0
        print(f"  t={hour:5.2f}h  requests={s['requests']:6d}  "
              f"severity={s['severity']:.1f}  "
              f"active_chips={s['chips_active']:4d}  "
              f"retunes={s['retunes']}  violations={s['violations']}")

    print(f"\n{'policy':12s} {'MWh(fac)':>9s} {'tCO2e':>7s} "
          f"{'USD':>8s} {'J/req':>8s} {'SLO viol':>9s} {'retunes':>8s}")
    nopg = report.policy_summary("NoPG")
    for s in report.summary:
        print(f"{s['policy']:12s} {s['facility_kwh']/1e3:9.2f} "
              f"{s['co2_kg']/1e3:7.2f} {s['cost_usd']:8.0f} "
              f"{s['j_per_request']:8.1f} "
              f"{s['slo_violation_rate']*100:8.2f}% "
              f"{s['retunes']:8d}")
    for pol in ("ReGate-HW", "ReGate-Full"):
        s = report.policy_summary(pol)
        sv = 1.0 - s["total_j"] / nopg["total_j"]
        print(f"  {pol} fleet energy saving vs NoPG: {sv*100:.1f}% "
              f"(${nopg['cost_usd'] - s['cost_usd']:.0f}/day)")

    print()
    check_reconciliation(report)


if __name__ == "__main__":
    main()
