"""A chaos day: the fleet of ``fleet_day.py`` under injected faults
(ISSUE 8).

Three fault severities (clean control, moderate, severe) are realized
into seeded chip/link fault timelines (``core.faults.fault_plan``) and
replayed through the fleet simulator by ``sweep_chaos``: chips fail and
repair on MTBF cycles, maintenance drains pull slices of the fleet,
ICI links flap / degrade / go down (re-lowering collectives onto the
detoured ring schedules), and occasional failures corrupt power-gating
control logic — forcing gated policies onto the NoPG-equivalent
fallback rung. The anti-thrash hysteresis governor re-tunes knobs
through it all, and every faulted scenario is also run under the
stateless governor as the thrash control.

  PYTHONPATH=src python examples/chaos_day.py [--backend jax]
  PYTHONPATH=src python examples/chaos_day.py --checkpoint /tmp/ck

The run is deterministic under the fixed seed (per-(chip, link) child
streams; each severity's timeline is keyed by the severity value's own
bit pattern, so the campaign composition never shifts a timeline). The
script asserts in-line the chaos-plane invariants: severity 0 is an
exact no-op versus the clean fleet run, per-epoch energy conserves to
<= 1e-9 relative, and the hysteresis governor retunes at most once per
fault transition while the stateless baseline thrashes at least as
often.

``--checkpoint DIR`` adds the guard plane's (ISSUE 9) kill–resume
demo: the script relaunches itself as a checkpointed subprocess with
``REPRO_GUARD_KILL`` armed, SIGKILLs it mid-campaign (epoch 60 of 96,
mid-epoch — no snapshot of that epoch exists), then resumes from DIR
in-process and asserts the resumed campaign is **bit-identical** to
the uninterrupted one — summary rows and per-epoch records.
"""
import argparse
import json
import math
import os
import signal
import subprocess
import sys
import time

from repro.core.fleet import FleetReport, sweep_fleet
from repro.core.policies import KnobGrid
from repro.core.sweep import SweepSession, sweep_chaos

from fleet_day import build_scenario

REL_TOL = 1e-9
# 0 is the clean control; 0.25 is a partial-degradation regime (pg
# faults come and go); at >= 1 on a 4096-chip fleet some pg-corrupted
# chip is essentially always down, so gated policies ride the NoPG
# fallback rung all day — the bottom of the degradation ladder
SEVERITIES = (0.0, 0.25, 1.0, 2.0)
KILL_EPOCH = 60   # mid-epoch SIGKILL target for the --checkpoint demo


def check_clean_noop(campaign, scenario, grid) -> None:
    """Severity 0 realizes the all-clean timeline — its report must be
    bit-identical to a plain (faultless, stateless) fleet run apart
    from the fault bookkeeping columns."""
    clean: FleetReport = sweep_fleet(scenario, grid)
    rep: FleetReport = campaign["baseline_reports"][0.0]
    assert rep.records == clean.records
    assert rep.epoch_summary == clean.epoch_summary
    print(f"clean control: severity-0 baseline is bit-identical to the "
          f"faultless run ({len(clean.records)} records)")


def check_energy_conservation(rep: FleetReport) -> None:
    for s in rep.summary:
        pol = s["policy"]
        direct = math.fsum(r["total_j"] for r in rep.records
                           if r["policy"] == pol) \
            + math.fsum(x["unallocated_idle_j"]
                        for x in rep.epoch_summary
                        if x["policy"] == pol)
        rel = abs(s["total_j"] - direct) / max(direct, 1e-300)
        assert rel <= REL_TOL, (pol, rel)


def campaign_payload(campaign) -> str:
    """The campaign's result payload, canonically serialized for the
    bit-identity assertion (guard bookkeeping differs between a
    checkpointed and a plain run and is excluded)."""
    def recs(reports):
        return {repr(sev): {"records": rep.records,
                            "epoch_summary": rep.epoch_summary,
                            "summary": rep.summary}
                for sev, rep in reports.items()}
    return json.dumps({"summary": campaign["summary"],
                       "reports": recs(campaign["reports"]),
                       "baseline": recs(campaign["baseline_reports"])},
                      sort_keys=True)


def demo_kill_resume(ckdir: str, reference: str, backend) -> None:
    """SIGKILL a checkpointed self-subprocess mid-campaign, resume
    from its checkpoint directory, assert bit-identical results."""
    cmd = [sys.executable, os.path.abspath(__file__),
           "--checkpoint", ckdir]
    if backend:
        cmd += ["--backend", backend]
    env = dict(os.environ,
               REPRO_GUARD_KILL=f"mid:{KILL_EPOCH}",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..",
                                 "src"),
                    os.path.dirname(__file__)]))
    proc = subprocess.run(cmd, env=env, capture_output=True)
    assert proc.returncode == -signal.SIGKILL, proc.stderr.decode()
    snaps = sorted(os.listdir(os.path.join(ckdir, "run0_hyst")))
    print(f"\nkill–resume demo: subprocess SIGKILLed mid-epoch "
          f"{KILL_EPOCH}; checkpoint holds {snaps}")

    t0 = time.perf_counter()
    resumed = sweep_chaos(build_scenario(),
                          KnobGrid(window_scale=(0.5, 1.0, 2.0),
                                   delay_scale=(1.0, 2.0)),
                          fault_severities=SEVERITIES,
                          checkpoint=ckdir)
    wall = time.perf_counter() - t0
    assert campaign_payload(resumed) == reference
    print(f"kill–resume demo: resumed campaign is bit-identical to "
          f"the uninterrupted run (summary + per-epoch records), "
          f"{wall:.2f}s wall")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default=None, choices=("numpy", "jax"),
                    help="array backend for every per-epoch batched "
                         "sweep call")
    ap.add_argument("--checkpoint", default=None, metavar="DIR",
                    help="run the guard-plane kill–resume demo against "
                         "this campaign checkpoint directory")
    args = ap.parse_args(argv)
    if args.backend:
        with SweepSession(backend=args.backend):
            return run(args.checkpoint, args.backend)
    return run(args.checkpoint, args.backend)


def run(checkpoint=None, backend=None):
    # armed child mode: the parent (below) relaunched us with
    # REPRO_GUARD_KILL set — run the checkpointed campaign directly
    # and die where the hook says; the parent resumes from our ruins
    if checkpoint is not None and os.environ.get("REPRO_GUARD_KILL"):
        sweep_chaos(build_scenario(),
                    KnobGrid(window_scale=(0.5, 1.0, 2.0),
                             delay_scale=(1.0, 2.0)),
                    fault_severities=SEVERITIES, checkpoint=checkpoint)
        return
    scenario = build_scenario()
    grid = KnobGrid(window_scale=(0.5, 1.0, 2.0),
                    delay_scale=(1.0, 2.0))
    t0 = time.perf_counter()
    campaign = sweep_chaos(scenario, grid,
                           fault_severities=SEVERITIES)
    wall = time.perf_counter() - t0

    n_runs = len(campaign["reports"]) + len(campaign["baseline_reports"])
    print(f"chaos day: {len(SEVERITIES)} severities x "
          f"{len(scenario.policies)} policies over "
          f"{scenario.n_epochs} epochs on {scenario.n_chips} chips "
          f"({n_runs} fleet runs, one batched sweep call per epoch) "
          f"in {wall:.2f}s wall")

    print("\nfault timelines:")
    for sev in SEVERITIES:
        tl = campaign["timelines"][sev]
        fs = campaign["reports"][sev].fault_summary
        print(f"  sev={sev:.1f}  faulted_epochs={fs['faulted_epochs']:3d}"
              f"  transitions={tl.n_transitions:3d}"
              f"  chips_down_max={fs['chips_down_max']:3d}"
              f"  link_fault_epochs={fs['link_fault_epochs']:3d}"
              f"  pg_fault_epochs={fs['pg_fault_epochs']:3d}"
              f"  repairs={len(fs['repair_epochs'])}")

    print(f"\n{'sev':>4s} {'policy':12s} {'retunes':>8s} {'base':>5s} "
          f"{'bound':>6s} {'worst regret':>13s} {'SLO viol':>9s} "
          f"{'recov':>6s} {'pg-fb':>6s} {'J/req':>8s}")
    for row in campaign["summary"]:
        print(f"{row['fault_severity']:4.1f} {row['policy']:12s} "
              f"{row['retunes']:8d} {row['baseline_retunes']:5d} "
              f"{row['n_transitions']:6d} "
              f"{row['worst_regret_frac']*100:12.2f}% "
              f"{row['slo_violation_rate']*100:8.2f}% "
              f"{row['recovery_epochs_max']:6d} "
              f"{row['pg_fallback_epochs']:6d} "
              f"{row['j_per_request']:8.1f}")

    # in-line invariants ------------------------------------------------
    check_clean_noop(campaign, scenario, grid)
    for sev in SEVERITIES:
        check_energy_conservation(campaign["reports"][sev])
        check_energy_conservation(campaign["baseline_reports"][sev])
    print(f"energy conservation: totals match per-record sums to "
          f"<= {REL_TOL:g} relative, all severities and policies")
    for row in campaign["summary"]:
        if row["fault_severity"] == 0.0:
            assert row["retunes"] <= row["n_transitions"] \
                + len(scenario.policies)
            continue
        # anti-thrash: the hysteresis governor never out-retunes the
        # stateless baseline, and stays within the transition bound
        # (plus the initial deployment per class x knob row)
        assert row["retunes"] <= row["baseline_retunes"], row
    print("anti-thrash: hysteresis retunes <= stateless baseline "
          "retunes on every faulted scenario")

    if checkpoint is not None:
        demo_kill_resume(checkpoint, campaign_payload(campaign),
                         backend)


if __name__ == "__main__":
    main()
