"""Reproduce the paper's headline analysis end-to-end: Figs 3/17/19/24
numbers for the whole Table-1 suite, plus the NPU-generation sweep.

  PYTHONPATH=src python examples/power_gating_study.py
"""
import statistics

from repro.core.carbon import yearly_carbon
from repro.core.hw import NPUS
from repro.core.opgen import paper_suite
from repro.core.policies import POLICIES, evaluate_all, savings_vs_nopg


def main():
    print(f"{'workload':24s} {'static%':>8s} "
          + "".join(f"{p:>13s}" for p in POLICIES[1:])
          + f" {'ovFull%':>9s} {'carbon%':>9s}")
    per_policy = {p: [] for p in POLICIES[1:]}
    for wl in paper_suite():
        reps = evaluate_all(wl, "NPU-D")
        sv = savings_vs_nopg(reps)
        ov = reps["ReGate-Full"].runtime_s / reps["NoPG"].runtime_s - 1
        c_no = yearly_carbon(reps["NoPG"].avg_power_w, "NPU-D", False)
        c_rg = yearly_carbon(reps["ReGate-Full"].avg_power_w, "NPU-D", True)
        carbon = 1 - c_rg.total_kg_per_year / c_no.total_kg_per_year
        row = f"{wl.name:24s} {reps['NoPG'].static_frac*100:7.1f}%"
        for p in POLICIES[1:]:
            per_policy[p].append(sv[p])
            row += f" {sv[p]*100:11.1f}%"
        print(row + f" {ov*100:8.3f}% {carbon*100:8.1f}%")
    print("-" * 110)
    print("averages: " + "  ".join(
        f"{p}={statistics.mean(v)*100:.1f}%" for p, v in per_policy.items()))
    print("paper:    ReGate-Full 8.5-32.8% (avg 15.5%), overhead <0.5%, "
          "carbon 31.1-62.9%")

    print("\nper-generation ReGate-Full savings (paper Fig 23):")
    for gen in NPUS:
        vals = [savings_vs_nopg(evaluate_all(w, gen))["ReGate-Full"]
                for w in paper_suite()]
        print(f"  {gen}: avg {statistics.mean(vals)*100:.1f}%  "
              f"range {min(vals)*100:.1f}-{max(vals)*100:.1f}%")


if __name__ == "__main__":
    main()
