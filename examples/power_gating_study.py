"""Reproduce the paper's headline analysis end-to-end on the batched
sweep plane: Figs 3/17/19/24 numbers for the whole Table-1 suite, the
NPU-generation sweep, and a full delay-scale knob-grid sensitivity
study — each section is ONE batched ``sweep`` call (suite × npus ×
policies × knobs evaluated in a handful of array passes), so the whole
study runs in seconds.

  PYTHONPATH=src python examples/power_gating_study.py [--backend jax]
                                                       [--fine-grid]

``--backend jax`` steers every sweep onto the jitted jax array backend;
``--fine-grid`` adds a §6.5-style ``sweep_grid`` sensitivity cube
(suite × 5 generations × {NoPG, ReGate-Full} × 240 crossed knobs =
40 800 cells — practical interactively because the jax backend
compiles the grid once; the per-workload sections pay one small
compile per distinct stack shape, so the jax backend shines on the
big repeated grids, not the 1-cell calls).
"""
import argparse
import statistics
import time

from repro.core.carbon import yearly_carbon
from repro.core.hw import NPUS
from repro.core.opgen import paper_suite
from repro.core.policies import POLICIES, PolicyKnobs, evaluate_all, \
    savings_vs_nopg
from repro.core.sweep import group_by, sweep, sweep_grid, with_savings


def fine_grid_study():
    """CompPow-style fine-knob cube: where does ReGate-Full's saving
    move fastest? One ``sweep_grid`` call, min/max over the cube."""
    t0 = time.perf_counter()
    recs = sweep_grid(
        paper_suite(), npus=tuple(NPUS),
        policies=("NoPG", "ReGate-Full"),
        delay_scale=(0.25, 0.5, 1.0, 2.0, 4.0, 8.0),
        leak_off_logic=(0.01, 0.03, 0.1, 0.2, 0.4),
        leak_sram_sleep=(0.1, 0.25, 0.4, 0.6),
        leak_sram_off=(0.002, 0.02),
        sa_width=(None, 256))  # §6.5 SA-width axis — a real knob now
    recs = with_savings(recs)
    print(f"\nfine-grid cube: {len(recs)} cells in "
          f"{time.perf_counter() - t0:.2f}s")
    for (gen,), rows in group_by(recs, "npu").items():
        sv = [r["savings"] for r in rows if r["policy"] == "ReGate-Full"]
        print(f"  {gen}: ReGate-Full savings across the knob cube "
              f"{min(sv)*100:.1f}% .. {max(sv)*100:.1f}%")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default=None, choices=("numpy", "jax"),
                    help="array backend for every sweep in the study")
    ap.add_argument("--fine-grid", action="store_true",
                    help="also run the 40,800-cell sensitivity cube "
                         "(suite x 5 gens x {NoPG, ReGate-Full} x 240 "
                         "crossed knobs)")
    args = ap.parse_args(argv)
    if args.backend:
        from repro.core.sweep import SweepSession
        with SweepSession(backend=args.backend):
            return _study(args)
    return _study(args)


def _study(args):
    t_start = time.perf_counter()
    print(f"{'workload':24s} {'static%':>8s} "
          + "".join(f"{p:>13s}" for p in POLICIES[1:])
          + f" {'ovFull%':>9s} {'carbon%':>9s}")
    per_policy = {p: [] for p in POLICIES[1:]}
    for wl in paper_suite():
        reps = evaluate_all(wl, "NPU-D")  # one batched pass, all policies
        sv = savings_vs_nopg(reps)
        ov = reps["ReGate-Full"].runtime_s / reps["NoPG"].runtime_s - 1
        c_no = yearly_carbon(reps["NoPG"].avg_power_w, "NPU-D", False)
        c_rg = yearly_carbon(reps["ReGate-Full"].avg_power_w, "NPU-D", True)
        carbon = 1 - c_rg.total_kg_per_year / c_no.total_kg_per_year
        row = f"{wl.name:24s} {reps['NoPG'].static_frac*100:7.1f}%"
        for p in POLICIES[1:]:
            per_policy[p].append(sv[p])
            row += f" {sv[p]*100:11.1f}%"
        print(row + f" {ov*100:8.3f}% {carbon*100:8.1f}%")
    print("-" * 110)
    print("averages: " + "  ".join(
        f"{p}={statistics.mean(v)*100:.1f}%" for p, v in per_policy.items()))
    print("paper:    ReGate-Full 8.5-32.8% (avg 15.5%), overhead <0.5%, "
          "carbon 31.1-62.9%")

    # --- Fig 23: all 5 generations in ONE batched sweep ---
    print("\nper-generation ReGate-Full savings (paper Fig 23, one "
          "batched sweep over suite x 5 gens):")
    recs = with_savings(sweep(paper_suite(), npus=tuple(NPUS),
                              policies=("NoPG", "ReGate-Full")))
    for (gen,), rows in group_by(recs, "npu").items():
        vals = [r["savings"] for r in rows if r["policy"] == "ReGate-Full"]
        print(f"  {gen}: avg {statistics.mean(vals)*100:.1f}%  "
              f"range {min(vals)*100:.1f}-{max(vals)*100:.1f}%")

    # --- Fig 22-style knob-grid study: suite x 6 delay scales, one call;
    # NoPG is knob-insensitive, so the baseline rides the knob-0 cell and
    # with_savings falls back to it for the other knob points ---
    scales = (0.25, 0.5, 1.0, 2.0, 4.0, 8.0)
    grid = [PolicyKnobs(delay_scale=s) for s in scales]
    full = sweep(paper_suite(), policies=("NoPG", "ReGate-Full"),
                 knob_grid=grid)
    pruned = [r for r in full
              if r["policy"] != "NoPG" or r["knob_idx"] == 0]
    recs = with_savings(pruned)
    print(f"\ndelay-scale sensitivity (suite x {len(scales)}-point knob "
          "grid, one batched sweep):")
    for (ki,), rows in group_by(recs, "knob_idx").items():
        fullr = [r for r in rows if r["policy"] == "ReGate-Full"]
        if not fullr:
            continue
        sv = statistics.mean(r["savings"] for r in fullr)
        print(f"  delay x{scales[ki]:<5g} ReGate-Full avg savings "
              f"{sv*100:.1f}%")
    if args.fine_grid:
        fine_grid_study()
    print(f"\ntotal study wall time: {time.perf_counter()-t_start:.2f}s")


if __name__ == "__main__":
    main()
