"""Quickstart: the paper's power-gating analysis in five lines, plus one
training step of an assigned architecture.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_arch
from repro.core.opgen import llm_workload
from repro.core.policies import evaluate_all, savings_vs_nopg
from repro.data.specs import make_batch
from repro.configs.base import ShapeConfig
from repro.models import registry
from repro.models.param import init_params
from repro.optim.adamw import AdamWConfig
from repro.train.steps import TrainState, make_train_step

# --- 1. ReGate: energy of an LLM decode workload under all five designs
wl = llm_workload("llama3-8b", "decode", batch=8, n_chips=1)
reports = evaluate_all(wl, "NPU-D")
savings = savings_vs_nopg(reports)
print("== ReGate energy savings vs NoPG (llama3-8b decode, NPU-D) ==")
for policy, s in savings.items():
    r = reports[policy]
    print(f"  {policy:12s} {s*100:6.2f}%   "
          f"avg power {r.avg_power_w:6.1f} W   "
          f"static fraction {r.static_frac:.2f}")

# --- 2. one train step of an assigned architecture (reduced, CPU)
cfg = get_arch("qwen3-32b").reduced()
opt = AdamWConfig(total_steps=10)
params = init_params(registry.param_specs(cfg), jax.random.PRNGKey(0))
state = TrainState.create(params, opt)
step = jax.jit(make_train_step(cfg, opt))
batch = make_batch(cfg, ShapeConfig("t", 64, 4, "train"), seed=0)
state, metrics = step(state, batch)
print(f"\n== qwen3-32b (reduced) train step: loss={float(metrics['loss']):.3f}"
      f" grad_norm={float(metrics['grad_norm']):.3f} ==")
