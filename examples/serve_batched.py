"""Serve a small model with batched requests (prefill + decode loop).

  PYTHONPATH=src python examples/serve_batched.py --arch qwen2.5-3b

Arrival-driven mode (ISSUE 7): feed the server a seeded fleet-plane
arrival trace (Poisson/diurnal/bursty, ``repro.core.fleet``) with
requests joining at the next epoch boundary:

  PYTHONPATH=src python examples/serve_batched.py --arrivals bursty \\
      --rate 2 --duration 20 --epoch 4
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main()
