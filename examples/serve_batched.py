"""Serve a small model with batched requests (prefill + decode loop).

  PYTHONPATH=src python examples/serve_batched.py --arch qwen2.5-3b
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main()
