"""End-to-end driver: train a ~100M-param model for a few hundred steps
with checkpointing and (simulated) failure recovery.

  PYTHONPATH=src python examples/train_e2e.py [--steps 300]

Uses mamba2-780m scaled to ~100M (24 layers, d=768) — attention-free, so
CPU steps stay fast enough for hundreds of steps.
"""
import argparse
import dataclasses
import tempfile

from repro.configs import get_arch
from repro.configs.base import SSMConfig, register
from repro.launch.train import TrainLoopConfig, run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    base = get_arch("mamba2-780m")
    cfg100m = dataclasses.replace(
        base, name="mamba2-100m", n_layers=24, d_model=768,
        vocab_size=50280,
        ssm=SSMConfig(d_state=64, head_dim=64, expand=2, conv_width=4,
                      n_groups=1, chunk=64))
    register(cfg100m)
    n = cfg100m.param_count()
    print(f"[e2e] mamba2-100m: {n/1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    with tempfile.TemporaryDirectory() as ckpt:
        out = run(TrainLoopConfig(
            arch="mamba2-100m", reduced=False, steps=args.steps,
            seq_len=args.seq, global_batch=args.batch,
            ckpt_dir=ckpt, checkpoint_every=100, log_every=20))
    first = out["losses"][0]
    last = sum(out["losses"][-10:]) / 10
    print(f"[e2e] loss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
